"""L2: the split transformer (HAT's three submodels), adapter Λ, Medusa heads.

Decoder-only LM (RMSNorm → causal MHA w/ RoPE → RMSNorm → SwiGLU, residual
around each), split per the paper:

- **input submodel**  ``w_L^m``  — embedding + first ``m`` decoder layers
  (on device);
- **middle submodel**            — layers ``m..n``  (in the cloud);
- **output submodel** ``H_L``    — final RMSNorm + LM head (on device);
- **adapter Λ**                  — one self-attention block (paper §3.4:
  "the same structure as the self-attention module of the decoder layer"),
  distilled from the middle submodel via Eq. 4;  the on-device draft model
  is ``w_S = H_L ∘ Λ ∘ w_L^m``;
- **Medusa heads**               — 4 ResBlock+linear heads on the deep
  hidden state (the U-Medusa baseline).

Every function exists in two flavours selected by ``use_pallas``: the
pure-jnp reference (used for training — interpret-mode pallas has no
efficient autodiff) and the L1 Pallas kernels (used on the AOT inference
path).  python/tests asserts the two are allclose.

KV caches are explicit ``[n_layers, 2, S, nh, hd]`` arrays threaded in and
out of every call (static-shape HLO); attention masks by absolute position,
so rolling back rejected draft tokens is just rewinding the position
counter (DESIGN.md §7).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import attention as K
from .kernels import ref as R


@dataclass(frozen=True)
class Config:
    vocab: int = 512
    hidden: int = 128
    layers: int = 8
    shallow_layers: int = 1      # m — on device
    heads: int = 4
    head_dim: int = 32
    ffn: int = 256
    max_seq: int = 640
    n_medusa: int = 4
    rope_theta: float = 10000.0

    @property
    def middle_layers(self) -> int:
        return self.layers - self.shallow_layers


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _dense(key, n_in, n_out):
    return jax.random.normal(key, (n_in, n_out)) * (n_in ** -0.5)


def init_layer(key, cfg: Config) -> dict:
    ks = jax.random.split(key, 7)
    h, f = cfg.hidden, cfg.ffn
    return {
        "ln1": jnp.ones((h,)),
        "wq": _dense(ks[0], h, h),
        "wk": _dense(ks[1], h, h),
        "wv": _dense(ks[2], h, h),
        "wo": _dense(ks[3], h, h),
        "ln2": jnp.ones((h,)),
        "wg": _dense(ks[4], h, f),
        "wu": _dense(ks[5], h, f),
        "wd": _dense(ks[6], f, h),
    }


def init_params(key, cfg: Config) -> dict:
    ks = jax.random.split(key, cfg.layers + 2)
    return {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.hidden)) * 0.02,
        "layers": [init_layer(ks[1 + i], cfg) for i in range(cfg.layers)],
        "final_ln": jnp.ones((cfg.hidden,)),
        "head": _dense(ks[-1], cfg.hidden, cfg.vocab),
    }


def init_adapter(key, cfg: Config) -> dict:
    """Λ: one self-attention block (ln + qkvo), same shape as a layer's
    attention half."""
    ks = jax.random.split(key, 4)
    h = cfg.hidden
    return {
        "ln1": jnp.ones((h,)),
        "wq": _dense(ks[0], h, h),
        "wk": _dense(ks[1], h, h),
        "wv": _dense(ks[2], h, h),
        "wo": _dense(ks[3], h, h),
    }


def init_medusa(key, cfg: Config) -> list[dict]:
    heads = []
    for i in range(cfg.n_medusa):
        k1, k2, key = jax.random.split(key, 3)
        heads.append({
            "w1": _dense(k1, cfg.hidden, cfg.hidden),
            "b1": jnp.zeros((cfg.hidden,)),
            "out": _dense(k2, cfg.hidden, cfg.vocab),
        })
    return heads


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Rotary embedding.  x: [T, nh, hd]; positions: [T] absolute."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half) / half)          # [half]
    ang = positions[:, None].astype(x.dtype) * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attn_block(h, p, kv, pos, cfg: Config, use_pallas: bool):
    """Shared attention block: returns (residual-added h, new kv [2,S,nh,hd])."""
    t = h.shape[0]
    nh, hd = cfg.heads, cfg.head_dim
    x = R.rmsnorm_ref(h, p["ln1"])
    q = (x @ p["wq"]).reshape(t, nh, hd)
    k = (x @ p["wk"]).reshape(t, nh, hd)
    v = (x @ p["wv"]).reshape(t, nh, hd)
    positions = pos + jnp.arange(t)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(kv[0], k, (pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(kv[1], v, (pos, 0, 0))
    if use_pallas:
        # AOT/inference path.  block_k sweep (EXPERIMENTS.md §Perf): one
        # kv block per head minimizes loop overhead at these cache sizes
        # while the per-head VMEM working set (2·S·hd·4B ≈ 164 kB) stays
        # far under a TPU core's VMEM.
        o = K.attention(q, k_cache, v_cache, pos, block_k=k_cache.shape[0])
    else:
        o = R.attention_ref(q, k_cache, v_cache, pos)    # [T, nh, hd]
    h = h + o.reshape(t, cfg.hidden) @ p["wo"]
    return h, jnp.stack([k_cache, v_cache])


def _ffn_block(h, p, cfg: Config, use_pallas: bool):
    x = R.rmsnorm_ref(h, p["ln2"])
    ffn_fn = K.swiglu if use_pallas else R.swiglu_ref
    return h + ffn_fn(x, p["wg"], p["wu"], p["wd"])


def decoder_layer(h, p, kv, pos, cfg: Config, use_pallas: bool):
    h, kv = _attn_block(h, p, kv, pos, cfg, use_pallas)
    h = _ffn_block(h, p, cfg, use_pallas)
    return h, kv


def _run_layers(h, layer_params, kv, pos, cfg: Config, use_pallas: bool):
    """kv: [L, 2, S, nh, hd].  Python loop (L is small & static)."""
    new_kv = []
    for i, p in enumerate(layer_params):
        h, kv_i = decoder_layer(h, p, kv[i], pos, cfg, use_pallas)
        new_kv.append(kv_i)
    return h, jnp.stack(new_kv)


# ---------------------------------------------------------------------------
# The three submodels + adapter + heads (cached/inference form)
# ---------------------------------------------------------------------------

def input_submodel(params, tokens, skv, pos, cfg: Config, use_pallas=True):
    """w_L^m: tokens [T] i32 → shallow hidden [T,H].  skv: [m,2,S,nh,hd]."""
    h = params["embed"][tokens]
    return _run_layers(h, params["layers"][: cfg.shallow_layers], skv, pos, cfg, use_pallas)


def middle_submodel(params, hidden, mkv, pos, cfg: Config, use_pallas=True):
    """Cloud side: shallow hidden [T,H] → deep hidden [T,H]."""
    return _run_layers(hidden, params["layers"][cfg.shallow_layers:], mkv, pos, cfg, use_pallas)


def output_head(params, hidden):
    """H_L: final norm + LM head.  hidden [T,H] → logits [T,V]."""
    return R.rmsnorm_ref(hidden, params["final_ln"]) @ params["head"]


def adapter_forward(ap, hidden, akv, pos, cfg: Config, use_pallas=True):
    """Λ: shallow hidden [T,H] → approx deep hidden [T,H].  akv: [2,S,nh,hd]."""
    return _attn_block(hidden, ap, akv, pos, cfg, use_pallas)


def draft_forward(params, ap, tokens, skv, akv, pos, cfg: Config, use_pallas=True):
    """Draft model w_S = H_L ∘ Λ ∘ w_L^m.

    Returns (logits [T,V], skv', akv', shallow_hidden [T,H]).  The shallow
    hidden states are returned so the device can buffer them during
    drafting and upload exactly those for verification (the paper's
    "hidden states of draft tokens") without recomputation.
    """
    h, skv = input_submodel(params, tokens, skv, pos, cfg, use_pallas)
    deep_approx, akv = adapter_forward(ap, h, akv, pos, cfg, use_pallas)
    return output_head(params, deep_approx), skv, akv, h


def medusa_forward(mheads, deep_hidden, params):
    """U-Medusa heads: deep hidden [T,H] → [n_medusa, T, V] logits.
    Head j predicts the token at offset j+2 (the base head predicts +1),
    as in Medusa.  Applied to the *normed* hidden state like the LM head."""
    x = R.rmsnorm_ref(deep_hidden, params["final_ln"])
    outs = []
    for hp in mheads:
        r = x + jax.nn.silu(x @ hp["w1"] + hp["b1"])
        outs.append(r @ hp["out"])
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# Training-form forward (full sequence, no external cache)
# ---------------------------------------------------------------------------

def full_forward(params, tokens, cfg: Config):
    """Single-sequence full forward used for training + distillation.

    tokens [T] → (logits [T,V], shallow_h [T,H], final_h [T,H])
    where final_h is the pre-final-norm hidden state (the distillation
    target f^L of Eq. 4).  Uses the jnp reference kernels (differentiable).
    Numerically identical to the cached path with pos=0, S=T (tested).
    """
    t = tokens.shape[0]
    zkv = jnp.zeros((cfg.layers, 2, t, cfg.heads, cfg.head_dim))
    h = params["embed"][tokens]
    shallow = None
    for i, p in enumerate(params["layers"]):
        h, _ = decoder_layer(h, p, zkv[i], 0, cfg, use_pallas=False)
        if i == cfg.shallow_layers - 1:
            shallow = h
    return output_head(params, h), shallow, h


def draft_train_forward(params, ap, tokens, cfg: Config):
    """Draft-model forward in training form (teacher-forced full sequence).
    Returns (draft_logits [T,V], f_S [T,H]) — f_S is Λ's approximation of
    the deep hidden state, compared against f_L in Eq. 4."""
    t = tokens.shape[0]
    zskv = jnp.zeros((cfg.shallow_layers, 2, t, cfg.heads, cfg.head_dim))
    zakv = jnp.zeros((2, t, cfg.heads, cfg.head_dim))
    h, _ = input_submodel(params, tokens, zskv, 0, cfg, use_pallas=False)
    f_s, _ = adapter_forward(ap, h, zakv, 0, cfg, use_pallas=False)
    return output_head(params, f_s), f_s


# ---------------------------------------------------------------------------
# Flat parameter ordering (shared with the rust side via manifest.json)
# ---------------------------------------------------------------------------

def flatten_weights(params, adapter, medusa, cfg: Config):
    """Deterministic name → array ordering for weights.npz and artifact
    parameter lists.  Rust feeds PJRT buffers in exactly this order."""
    out: list[tuple[str, jnp.ndarray]] = [("embed", params["embed"])]
    for i, p in enumerate(params["layers"]):
        for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"):
            out.append((f"layers.{i}.{k}", p[k]))
    out.append(("final_ln", params["final_ln"]))
    out.append(("head", params["head"]))
    for k in ("ln1", "wq", "wk", "wv", "wo"):
        out.append((f"adapter.{k}", adapter[k]))
    for i, hp in enumerate(medusa):
        for k in ("w1", "b1", "out"):
            out.append((f"medusa.{i}.{k}", hp[k]))
    return out


def param_count(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
