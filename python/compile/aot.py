"""AOT pipeline: train (cached) → lower every artifact to HLO text →
write manifest.json + weights.npz + prompts.bin.

Run once by ``make artifacts``; the rust coordinator is self-contained
afterwards.  Interchange is HLO **text** — the image's xla_extension 0.5.1
rejects jax≥0.5's 64-bit-id serialized protos, while the text parser
reassigns ids (see /opt/xla-example/README.md).

Weights are **runtime parameters**, not baked constants: rust loads
weights.npz once, uploads each array as a device-resident PJRT buffer, and
passes them to every execute — keeping the HLO files small and the weights
shared across all token-bucket variants.

Usage:  cd python && python -m compile.aot --out ../artifacts
Env:    HAT_AOT_QUICK=1   fewer training steps + buckets (CI smoke)
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, train
from .model import (Config, adapter_forward, draft_forward, flatten_weights,
                    input_submodel, medusa_forward, output_head, _run_layers,
                    param_count)

QUICK = os.environ.get("HAT_AOT_QUICK", "") not in ("", "0")
BUCKETS = [1, 4, 16, 64, 256] if QUICK else [1, 2, 4, 8, 16, 32, 64, 128, 256]


# ---------------------------------------------------------------------------
# HLO text lowering (the interchange gotcha lives here)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Weight (un)flattening shared with model.flatten_weights ordering
# ---------------------------------------------------------------------------


def rebuild(names, arrays):
    """Rebuild nested param structures from flat (name, array) pairs.
    Supports keys like 'embed', 'layers.3.wq', 'adapter.ln1', 'medusa.0.w1'.
    Integer-keyed levels become lists ordered by index (indices need not
    start at 0 — e.g. the middle submodel's layers m..L-1)."""
    params: dict = {}
    for name, arr in zip(names, arrays):
        parts = name.split(".")
        node = params
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def listify(d):
        if isinstance(d, dict):
            if d and all(k.isdigit() for k in d):
                return [listify(d[k]) for k in sorted(d, key=int)]
            return {k: listify(v) for k, v in d.items()}
        return d
    return listify(params)


# ---------------------------------------------------------------------------
# Artifact definitions
# ---------------------------------------------------------------------------


def artifact_defs(cfg: Config, weight_names_all: list[str]):
    """Returns [(kind, t_bucket, weight_names, fn, dyn_specs, out_specs,
    donate)] where donate lists the *dynamic-arg offsets* of KV caches —
    donated to XLA so cache updates happen in place instead of copying
    multi-MB buffers every call (EXPERIMENTS.md §Perf).

    fn takes (*weights, *dynamic) with dynamic args matching dyn_specs —
    a list of (name, shape, dtype).  All artifacts are lowered with
    return_tuple=True; rust unwraps the tuple.
    """
    m, L = cfg.shallow_layers, cfg.layers
    nh, hd, H, V, S = cfg.heads, cfg.head_dim, cfg.hidden, cfg.vocab, cfg.max_seq

    lm_names = ["embed"] + [f"layers.{i}.{k}" for i in range(m)
                            for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")]
    mid_names = [f"layers.{i}.{k}" for i in range(m, L)
                 for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")]
    head_names = ["final_ln", "head"]
    ad_names = [f"adapter.{k}" for k in ("ln1", "wq", "wk", "wv", "wo")]
    med_names = ["final_ln"] + [f"medusa.{i}.{k}" for i in range(cfg.n_medusa)
                                for k in ("w1", "b1", "out")]

    f32, i32 = "f32", "i32"
    defs = []

    def w(names):
        missing = [n for n in names if n not in weight_names_all]
        assert not missing, missing
        return names

    for t in BUCKETS:
        # --- device input submodel: tokens -> shallow hidden -----------------
        def di_fn(*args, _t=t, _names=tuple(lm_names)):
            nw = len(_names)
            p = rebuild(_names, args[:nw])
            tokens, skv, pos = args[nw:]
            h, skv2 = input_submodel(p, tokens, skv, pos, cfg, use_pallas=True)
            return h, skv2
        defs.append(("device_input", t, w(lm_names), di_fn, [
            ("tokens", (t,), i32),
            ("skv", (m, 2, S, nh, hd), f32),
            ("pos", (), i32),
        ], [("hidden", (t, H)), ("skv", (m, 2, S, nh, hd))], [1]))

        # --- cloud middle submodel: shallow hidden -> deep hidden ------------
        def cm_fn(*args, _t=t, _names=tuple(mid_names)):
            nw = len(_names)
            p = rebuild(_names, args[:nw])
            hidden, mkv, pos = args[nw:]
            deep, mkv2 = _run_layers(hidden, p["layers"], mkv, pos, cfg, use_pallas=True)
            return deep, mkv2
        defs.append(("cloud_middle", t, w(mid_names), cm_fn, [
            ("hidden", (t, H), f32),
            ("mkv", (L - m, 2, S, nh, hd), f32),
            ("pos", (), i32),
        ], [("deep", (t, H)), ("mkv", (L - m, 2, S, nh, hd))], [1]))

        # --- device head: deep hidden -> logits ------------------------------
        def dh_fn(*args, _t=t, _names=tuple(head_names)):
            nw = len(_names)
            p = rebuild(_names, args[:nw])
            (deep,) = args[nw:]
            return (output_head(p, deep),)
        defs.append(("device_head", t, w(head_names), dh_fn, [
            ("deep", (t, H), f32),
        ], [("logits", (t, V))], []))

        # --- adapter prefill: fill Λ's KV over prompt hidden states ----------
        def ap_fn(*args, _t=t, _names=tuple(ad_names)):
            nw = len(_names)
            p = rebuild(_names, args[:nw])["adapter"]
            hidden, akv, pos = args[nw:]
            _, akv2 = adapter_forward(p, hidden, akv, pos, cfg, use_pallas=True)
            return (akv2,)
        defs.append(("adapter_prefill", t, w(ad_names), ap_fn, [
            ("hidden", (t, H), f32),
            ("akv", (2, S, nh, hd), f32),
            ("pos", (), i32),
        ], [("akv", (2, S, nh, hd))], [1]))

    # --- draft step (T=1): one autoregressive draft-model step ---------------
    draft_names = lm_names + ad_names + head_names

    def ds_fn(*args, _names=tuple(draft_names)):
        nw = len(_names)
        p = rebuild(_names, args[:nw])
        lm = {"embed": p["embed"], "layers": p["layers"],
              "final_ln": p["final_ln"], "head": p["head"]}
        tokens, skv, akv, pos = args[nw:]
        logits, skv2, akv2, shallow = draft_forward(
            lm, p["adapter"], tokens, skv, akv, pos, cfg, use_pallas=True)
        return logits, skv2, akv2, shallow
    defs.append(("draft_step", 1, w(draft_names), ds_fn, [
        ("tokens", (1,), i32),
        ("skv", (m, 2, S, nh, hd), f32),
        ("akv", (2, S, nh, hd), f32),
        ("pos", (), i32),
    ], [("logits", (1, V)), ("skv", (m, 2, S, nh, hd)),
        ("akv", (2, S, nh, hd)), ("shallow", (1, H))], [1, 2]))

    # --- medusa decode (T=1): deep hidden -> n_medusa logit sets -------------
    def md_fn(*args, _names=tuple(med_names)):
        nw = len(_names)
        p = rebuild(_names, args[:nw])
        (deep,) = args[nw:]
        logits = medusa_forward(p["medusa"], deep, {"final_ln": p["final_ln"]})
        return (logits,)
    defs.append(("medusa_decode", 1, w(med_names), md_fn, [
        ("deep", (1, H), f32),
    ], [("medusa_logits", (cfg.n_medusa, 1, V))], []))

    return defs


_DT = {"f32": jnp.float32, "i32": jnp.int32}


def lower_artifact(fn, weight_arrays, dyn_specs, donate=()):
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in weight_arrays]
    specs += [jax.ShapeDtypeStruct(shape, _DT[dt]) for _, shape, dt in dyn_specs]
    nw = len(weight_arrays)
    # keep_unused: XLA must see every declared parameter even when DCE'd
    # (e.g. adapter_prefill discards the output projection) — the rust side
    # feeds the full weight list per the manifest contract.
    # donate: KV-cache inputs alias their output slots (in-place update).
    lowered = jax.jit(
        fn, keep_unused=True, donate_argnums=tuple(nw + i for i in donate)
    ).lower(*specs)
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# prompts.bin
# ---------------------------------------------------------------------------


def write_prompts(path: str, seed: int = 7):
    """Pool of in-distribution prompts; rust samples by target length.
    Format: magic 'HATP', u32 count, then per prompt u32 len + u32 tokens."""
    lengths = []
    for l in range(16, 577, 8):
        lengths += [l] * 3
    prompts = corpus.sample_prompts(seed, lengths)
    with open(path, "wb") as f:
        f.write(b"HATP")
        f.write(struct.pack("<I", len(prompts)))
        for p in prompts:
            f.write(struct.pack("<I", len(p)))
            f.write(np.asarray(p, dtype="<u4").tobytes())
    return len(prompts)


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def ensure_weights(cfg: Config, out_dir: str, retrain: bool):
    wpath = os.path.join(out_dir, "weights.npz")
    if os.path.exists(wpath) and not retrain:
        print(f"[aot] reusing {wpath}")
        data = np.load(wpath)
        flat = [(k, jnp.asarray(data[k])) for k in data.files]
        names = [k for k, _ in flat]
        tree = rebuild(names, [a for _, a in flat])
        meta_path = os.path.join(out_dir, "train_meta.json")
        meta = json.load(open(meta_path)) if os.path.exists(meta_path) else {}
        return tree, names, meta

    lm_steps, distill_steps, medusa_steps = (150, 150, 80) if QUICK else (700, 1600, 350)
    params, losses = train.train_lm(cfg, lm_steps)
    adapter, dloss = train.distill_adapter(params, cfg, distill_steps)
    mheads, mloss = train.train_medusa(params, cfg, medusa_steps)
    accept = train.measure_accept_length(params, adapter, cfg)
    print(f"[aot] measured accept length (greedy, η=0.6): {accept:.2f}")

    flat = flatten_weights(params, adapter, mheads, cfg)
    np.savez(wpath, **{k: np.asarray(v) for k, v in flat})
    meta = {
        "lm_final_loss": losses[-1],
        "distill_final_loss": dloss,
        "medusa_final_loss": mloss,
        "accept_length_probe": accept,
        "lm_params": param_count(params),
        "adapter_params": param_count(adapter),
        "medusa_params": param_count(mheads),
    }
    json.dump(meta, open(os.path.join(out_dir, "train_meta.json"), "w"), indent=1)
    names = [k for k, _ in flat]
    return rebuild(names, [a for _, a in flat]), names, meta


def write_golden(cfg: Config, out_dir: str, by_name):
    """Golden generation trace for cross-language verification: rust's
    engine (PJRT, cached KV, bucket padding) must reproduce these tokens
    exactly.  Uses the *training-form* forward — python/tests proves the
    cached path is numerically identical to it."""
    from .model import full_forward, draft_train_forward
    from . import corpus as _corpus

    names = list(by_name.keys())
    tree = rebuild(names, [by_name[n] for n in names])
    params = {"embed": tree["embed"], "layers": tree["layers"],
              "final_ln": tree["final_ln"], "head": tree["head"]}
    adapter = tree["adapter"]

    gen = _corpus.CorpusGenerator(555)
    prompt = gen.document(32, 32)
    full_fn = jax.jit(lambda t: full_forward(params, t, cfg)[0])
    draft_fn = jax.jit(lambda t: draft_train_forward(params, adapter, t, cfg)[0])

    ctx = list(prompt)
    for _ in range(24):
        ctx.append(int(jnp.argmax(full_fn(jnp.asarray(ctx, jnp.int32))[-1])))
    full_gen = ctx[len(prompt):]

    ctx = list(prompt)
    draft_probs = []
    for _ in range(24):
        lg = draft_fn(jnp.asarray(ctx, jnp.int32))[-1]
        p = jax.nn.softmax(lg)
        tok = int(jnp.argmax(lg))
        draft_probs.append(round(float(p[tok]), 6))
        ctx.append(tok)
    draft_gen = ctx[len(prompt):]

    golden = {
        "prompt": [int(t) for t in prompt],
        "full_greedy": [int(t) for t in full_gen],
        "draft_greedy": [int(t) for t in draft_gen],
        "draft_probs": draft_probs,
    }
    json.dump(golden, open(os.path.join(out_dir, "golden.json"), "w"), indent=1)
    print(f"[aot] golden trace written (full: {full_gen[:6]}..., draft: {draft_gen[:6]}...)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = Config()
    _tree, names, meta = ensure_weights(cfg, args.out, args.retrain)
    # Flat name -> array lookup for artifact lowering.
    data = np.load(os.path.join(args.out, "weights.npz"))
    by_name = {k: jnp.asarray(data[k]) for k in data.files}

    n_prompts = write_prompts(os.path.join(args.out, "prompts.bin"))
    print(f"[aot] wrote {n_prompts} prompts")
    write_golden(cfg, args.out, by_name)

    manifest = {
        "model": {
            "vocab": cfg.vocab, "hidden": cfg.hidden, "layers": cfg.layers,
            "shallow_layers": cfg.shallow_layers, "heads": cfg.heads,
            "head_dim": cfg.head_dim, "ffn": cfg.ffn, "max_seq": cfg.max_seq,
            "n_medusa": cfg.n_medusa,
        },
        "buckets": BUCKETS,
        "weights_file": "weights.npz",
        "prompts_file": "prompts.bin",
        "train_meta": meta,
        "artifacts": [],
    }

    t0 = time.time()
    for kind, t, wnames, fn, dyn_specs, out_specs, donate in artifact_defs(cfg, names):
        name = f"{kind}_{t}"
        fname = f"{name}.hlo.txt"
        arrays = [by_name[n] for n in wnames]
        text = lower_artifact(fn, arrays, dyn_specs, donate)
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name, "kind": kind, "t": t, "file": fname,
            "weights": wnames,
            "inputs": [{"name": n, "shape": list(s), "dtype": d}
                       for n, s, d in dyn_specs],
            "outputs": [{"name": n, "shape": list(s)} for n, s in out_specs],
        })
        print(f"[aot] lowered {name} ({len(text) / 1e3:.0f} kB, "
              f"{time.time() - t0:.0f}s elapsed)", flush=True)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts, "
          f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
