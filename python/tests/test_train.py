"""Training-pipeline tests: the hand-rolled Adam, the Eq. 4 distillation
loss, the corpus generator, and (slow-marked) short end-to-end training.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus, train
from compile.model import Config

# vocab must cover the corpus (tokens < 512): out-of-vocab targets make
# take_along_axis fill NaN inside the CE loss.
CFG = Config(vocab=512, hidden=64, layers=2, shallow_layers=1, heads=2,
             head_dim=32, ffn=128, max_seq=128)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def test_adam_minimizes_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = train.adam_init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = train.adam_update(params, g, opt, lr=0.1)
    assert float(loss(params)) < 1e-3


def test_adam_bias_correction_first_step():
    """After one step with gradient g, update ≈ lr · sign(g)."""
    params = {"x": jnp.asarray([1.0])}
    opt = train.adam_init(params)
    grads = {"x": jnp.asarray([0.3])}
    new, _ = train.adam_update(params, grads, opt, lr=0.01)
    assert abs(float(new["x"][0]) - (1.0 - 0.01)) < 1e-4


# ---------------------------------------------------------------------------
# Losses (Eq. 4 pieces)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_smooth_l1_properties(seed):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (8, 4))
    assert float(train.smooth_l1(x, x)) == 0.0
    y = x + 0.5
    # Below beta the loss is quadratic: 0.5 * d^2
    assert abs(float(train.smooth_l1(x, y)) - 0.5 * 0.25) < 1e-6
    # Far apart it is linear: |d| - 0.5
    z = x + 10.0
    assert abs(float(train.smooth_l1(x, z)) - 9.5) < 1e-5


def test_soft_ce_minimized_at_teacher():
    t = jnp.asarray([[2.0, 0.0, -1.0]])
    ce_self = float(train.soft_ce(t, t))
    ce_other = float(train.soft_ce(t, jnp.asarray([[0.0, 2.0, -1.0]])))
    assert ce_self < ce_other


def test_cross_entropy_perfect_prediction():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
    targets = jnp.asarray([0, 1])
    assert float(train.cross_entropy(logits, targets)) < 1e-3


# ---------------------------------------------------------------------------
# Corpus
# ---------------------------------------------------------------------------


def test_corpus_deterministic_and_in_vocab():
    a = corpus.CorpusGenerator(7).stream(5000)
    b = corpus.CorpusGenerator(7).stream(5000)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < corpus.VOCAB


def test_corpus_has_predictable_structure():
    """The bigram preferences must make a corpus that is compressible —
    subject→verb transitions hit the preferred verb most of the time.
    This is what gives speculative decoding its accept length."""
    gen = corpus.CorpusGenerator(3)
    hits, total = 0, 0
    for _ in range(500):
        s = gen.sentence()
        for i, t in enumerate(s[:-1]):
            if t in range(corpus.SUBJ[0], corpus.SUBJ[-1] + 1):
                total += 1
                if s[i + 1] == gen.subj2verb[t - corpus.SUBJ[0]][0]:
                    hits += 1
    assert total > 0
    assert hits / total > 0.5, f"preferred-verb rate {hits/total}"


def test_document_length_contract():
    gen = corpus.CorpusGenerator(1)
    for n in [16, 100, 333]:
        d = gen.document(n, n)
        assert len(d) == n
        assert d[0] == corpus.BOS


def test_training_batches_shapes_and_shift():
    it = corpus.training_batches(0, n_tokens=5000, batch=4, seqlen=32)
    x, y = next(it)
    assert x.shape == (4, 32) and y.shape == (4, 32)
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])


def test_sample_prompts_lengths():
    ps = corpus.sample_prompts(0, [16, 64, 128])
    assert [len(p) for p in ps] == [16, 64, 128]


# ---------------------------------------------------------------------------
# Short end-to-end training (slow-ish; tiny model)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_short_training_reduces_loss():
    # 20-step LR warmup, then ~60 effective steps — expect a clear drop.
    _, losses = train.train_lm(CFG, steps=80, batch=4, seqlen=64, log_every=50)
    early = sum(losses[:5]) / 5
    late = sum(losses[-5:]) / 5
    assert late < early * 0.9, f"loss {early} -> {late}"


@pytest.mark.slow
def test_distillation_loss_decreases():
    params, _ = train.train_lm(CFG, steps=30, batch=4, seqlen=64, log_every=50)
    _, final = train.distill_adapter(params, CFG, steps=40, batch=4,
                                     seqlen=64, log_every=50)
    adapter0 = train.distill_adapter(params, CFG, steps=1, batch=4,
                                     seqlen=64, log_every=50)
    assert final < adapter0[1]
