"""AOT pipeline tests: weight (un)flattening, artifact definitions,
prompts.bin format, HLO lowering, and (if built) the shipped manifest.
"""

import json
import os
import struct
import tempfile

import jax
import numpy as np
import pytest

from compile import aot
from compile.model import (Config, flatten_weights, init_adapter, init_medusa,
                           init_params)

CFG = Config()


@pytest.fixture(scope="module")
def flat():
    params = init_params(jax.random.PRNGKey(0), CFG)
    ad = init_adapter(jax.random.PRNGKey(1), CFG)
    mh = init_medusa(jax.random.PRNGKey(2), CFG)
    return flatten_weights(params, ad, mh, CFG)


def test_rebuild_roundtrips_flatten(flat):
    names = [k for k, _ in flat]
    tree = aot.rebuild(names, [a for _, a in flat])
    assert tree["embed"].shape == (CFG.vocab, CFG.hidden)
    assert len(tree["layers"]) == CFG.layers
    assert tree["layers"][3]["wq"].shape == (CFG.hidden, CFG.hidden)
    assert set(tree["adapter"]) == {"ln1", "wq", "wk", "wv", "wo"}
    assert len(tree["medusa"]) == CFG.n_medusa


def test_rebuild_handles_offset_indices():
    """Middle-submodel weights start at layer m, not 0."""
    names = ["layers.3.wq", "layers.5.wq", "layers.4.wq"]
    arrays = [np.full((1,), i) for i in (3, 5, 4)]
    tree = aot.rebuild(names, arrays)
    got = [int(x["wq"][0]) for x in tree["layers"]]
    assert got == [3, 4, 5]


def test_artifact_defs_inventory(flat):
    names = [k for k, _ in flat]
    defs = aot.artifact_defs(CFG, names)
    kinds = {}
    for kind, t, wnames, fn, dyn, outs, donate in defs:
        kinds.setdefault(kind, []).append(t)
        # every weight must exist and every dynamic spec be well-formed
        assert all(n in names for n in wnames)
        assert all(len(s) >= 0 and d in ("f32", "i32") for _, s, d in dyn)
    assert sorted(kinds["device_input"]) == aot.BUCKETS
    assert sorted(kinds["cloud_middle"]) == aot.BUCKETS
    assert sorted(kinds["device_head"]) == aot.BUCKETS
    assert sorted(kinds["adapter_prefill"]) == aot.BUCKETS
    assert kinds["draft_step"] == [1]
    assert kinds["medusa_decode"] == [1]


def test_lowering_emits_parsable_hlo(flat):
    """Lower one small artifact and check the HLO text contract:
    ENTRY present, parameter count = weights + dynamics (keep_unused!)."""
    names = [k for k, _ in flat]
    by_name = dict(flat)
    defs = aot.artifact_defs(CFG, names)
    kind, t, wnames, fn, dyn, outs, donate = next(
        d for d in defs if d[0] == "adapter_prefill" and d[1] == 1)
    text = aot.lower_artifact(fn, [by_name[n] for n in wnames], dyn)
    assert "ENTRY" in text
    # Count parameter instructions inside the ENTRY computation only
    # (inner fusion computations also contain parameter() instructions).
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    end = next(i for i in range(start + 1, len(lines)) if lines[i].startswith("}"))
    n_params = sum(" parameter(" in l for l in lines[start:end])
    assert n_params == len(wnames) + len(dyn), (
        f"{n_params} entry params vs {len(wnames)} weights + {len(dyn)} "
        f"dynamics (keep_unused regression?)")


def test_prompts_bin_roundtrip(tmp_path):
    path = str(tmp_path / "prompts.bin")
    n = aot.write_prompts(path, seed=3)
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == b"HATP"
    (count,) = struct.unpack_from("<I", data, 4)
    assert count == n
    off = 8
    lens = []
    for _ in range(count):
        (l,) = struct.unpack_from("<I", data, off)
        off += 4
        toks = np.frombuffer(data, dtype="<u4", count=l, offset=off)
        off += 4 * l
        assert toks.max() < CFG.vocab
        lens.append(l)
    assert off == len(data), "trailing bytes"
    assert min(lens) == 16 and max(lens) == 576


@pytest.mark.skipif(not os.path.exists("../artifacts/manifest.json"),
                    reason="artifacts not built")
def test_shipped_manifest_consistent():
    with open("../artifacts/manifest.json") as f:
        m = json.load(f)
    assert m["model"]["hidden"] == CFG.hidden
    arts = {a["name"]: a for a in m["artifacts"]}
    assert len(arts) == 4 * len(m["buckets"]) + 2
    for a in arts.values():
        assert os.path.exists(os.path.join("../artifacts", a["file"]))
    # weight names referenced exist in the npz
    wz = np.load("../artifacts/weights.npz")
    for a in arts.values():
        for wname in a["weights"]:
            assert wname in wz.files, wname


@pytest.mark.skipif(not os.path.exists("../artifacts/golden.json"),
                    reason="artifacts not built")
def test_shipped_golden_is_self_consistent():
    with open("../artifacts/golden.json") as f:
        g = json.load(f)
    assert len(g["full_greedy"]) == 24
    assert len(g["draft_greedy"]) == len(g["draft_probs"]) == 24
    assert all(0 <= t < CFG.vocab for t in g["prompt"] + g["full_greedy"])
