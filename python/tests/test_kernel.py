"""L1 kernel correctness: Pallas flash-attention and fused SwiGLU vs the
pure-jnp oracles, swept over shapes/dtypes with hypothesis.

This is the CORE correctness signal for the compute hot-spot: the same
kernel code lowers into every cloud_middle / device_input / draft_step
artifact the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 24),
    s_blocks=st.integers(1, 4),
    nh=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([32, 64, 128]),
    pos_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(t, s_blocks, nh, hd, block_k, pos_frac, seed):
    s = s_blocks * block_k
    pos = int(pos_frac * max(s - t, 0))
    q = rand(seed, (t, nh, hd))
    k = rand(seed + 1, (s, nh, hd))
    v = rand(seed + 2, (s, nh, hd))
    got = A.attention(q, k, v, jnp.asarray(pos, jnp.int32), block_k=block_k)
    want = R.attention_ref(q, k, v, pos)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 32),
    h=st.sampled_from([16, 64, 128]),
    f_blocks=st.integers(1, 3),
    block_f=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**16),
)
def test_swiglu_matches_ref(t, h, f_blocks, block_f, seed):
    f = f_blocks * block_f
    x = rand(seed, (t, h))
    wg = rand(seed + 1, (h, f)) * 0.1
    wu = rand(seed + 2, (h, f)) * 0.1
    wd = rand(seed + 3, (f, h)) * 0.1
    got = A.swiglu(x, wg, wu, wd, block_f=block_f)
    want = R.swiglu_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_pos_zero_is_pure_causal():
    """pos=0 with S=T equals classic causal self-attention."""
    t = 16
    q = rand(0, (t, 2, 16))
    k = rand(1, (t * 0 + 64, 2, 16))  # S=64 (one block), garbage tail masked
    v = rand(2, (64, 2, 16))
    got = A.attention(q, k, v, jnp.asarray(0, jnp.int32), block_k=64)
    want = R.attention_ref(q, k, v, 0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_garbage_tail_is_ignored():
    """Cache rows beyond pos+T must not influence the output."""
    t, s, nh, hd = 4, 128, 2, 16
    pos = 10
    q = rand(3, (t, nh, hd))
    k = rand(4, (s, nh, hd))
    v = rand(5, (s, nh, hd))
    out1 = A.attention(q, k, v, jnp.asarray(pos, jnp.int32))
    # Scribble over the masked tail.
    k2 = k.at[pos + t:].set(999.0)
    v2 = v.at[pos + t:].set(-999.0)
    out2 = A.attention(q, k2, v2, jnp.asarray(pos, jnp.int32))
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_attention_rejects_misaligned_cache():
    q = rand(0, (2, 2, 16))
    k = rand(1, (100, 2, 16))  # 100 not a multiple of 128
    with pytest.raises(ValueError, match="multiple of block_k"):
        A.attention(q, k, k, jnp.asarray(0, jnp.int32), block_k=128)


def test_swiglu_rejects_misaligned_ffn():
    x = rand(0, (2, 16))
    w = rand(1, (16, 100))
    with pytest.raises(ValueError, match="multiple of block_f"):
        A.swiglu(x, w, w, rand(2, (100, 16)), block_f=128)


def test_attention_rows_are_softmax_convex_combinations():
    """Each output is a convex combination of visible V rows: bounded by
    the min/max of the visible values per dim."""
    t, s, nh, hd = 3, 64, 1, 8
    pos = 5
    q = rand(7, (t, nh, hd))
    k = rand(8, (s, nh, hd))
    v = rand(9, (s, nh, hd))
    out = np.asarray(A.attention(q, k, v, jnp.asarray(pos, jnp.int32), block_k=64))
    v_np = np.asarray(v)
    for i in range(t):
        visible = v_np[: pos + i + 1, 0]  # [vis, hd]
        assert (out[i, 0] <= visible.max(0) + 1e-5).all()
        assert (out[i, 0] >= visible.min(0) - 1e-5).all()


def test_vmem_and_mxu_estimators():
    """Perf-model sanity: smaller kv blocks shrink VMEM; MXU utilization
    grows with tile fill and caps at 1."""
    v_small = A.vmem_footprint_bytes(8, 640, 32, 64)
    v_big = A.vmem_footprint_bytes(8, 640, 32, 256)
    assert v_small < v_big
    assert A.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert A.mxu_utilization_estimate(1, 32, 128) < 0.01
