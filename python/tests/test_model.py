"""L2 model correctness: the cached/chunked/split inference paths must be
numerically identical to the training-form full forward — the property the
whole HAT protocol (and the rust golden tests) stands on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus
from compile.model import (Config, adapter_forward, draft_forward,
                           draft_train_forward, full_forward, init_adapter,
                           init_medusa, init_params, input_submodel,
                           medusa_forward, middle_submodel, output_head,
                           param_count)

CFG = Config(vocab=128, hidden=64, layers=4, shallow_layers=1, heads=2,
             head_dim=32, ffn=128, max_seq=128)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def adapter():
    return init_adapter(jax.random.PRNGKey(1), CFG)


def toks(n, seed=0):
    gen = corpus.CorpusGenerator(seed)
    return jnp.asarray(gen.document(n, n), jnp.int32) % CFG.vocab


def zkv(layers):
    return jnp.zeros((layers, 2, CFG.max_seq, CFG.heads, CFG.head_dim))


def split_forward(params, tokens, chunks, use_pallas):
    """Run the split pipeline (input → middle → head) with KV caches over
    `chunks`, returning logits for every position."""
    skv = zkv(CFG.shallow_layers)
    mkv = zkv(CFG.layers - CFG.shallow_layers)
    pos = 0
    logits = []
    for c in chunks:
        seg = tokens[pos:pos + c]
        h, skv = input_submodel(params, seg, skv, pos, CFG, use_pallas)
        deep, mkv = middle_submodel(params, h, mkv, pos, CFG, use_pallas)
        logits.append(output_head(params, deep))
        pos += c
    return jnp.concatenate(logits, axis=0)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(4, 48), chunk=st.integers(1, 16), seed=st.integers(0, 99))
def test_split_cached_equals_full_forward(params, n, chunk, seed):
    tokens = toks(n, seed)
    full_logits, _, _ = full_forward(params, tokens, CFG)
    chunks = []
    left = n
    while left > 0:
        chunks.append(min(chunk, left))
        left -= chunks[-1]
    split_logits = split_forward(params, tokens, chunks, use_pallas=False)
    np.testing.assert_allclose(split_logits, full_logits, rtol=2e-4, atol=2e-4)


def test_pallas_path_equals_ref_path(params):
    tokens = toks(24, 3)
    a = split_forward(params, tokens, [24], use_pallas=True)
    b = split_forward(params, tokens, [8, 8, 8], use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_draft_cached_equals_teacher_forced(params, adapter):
    """Token-by-token cached draft model == full-sequence training form."""
    tokens = toks(20, 5)
    want, _ = draft_train_forward(params, adapter, tokens, CFG)

    skv = zkv(CFG.shallow_layers)
    akv = jnp.zeros((2, CFG.max_seq, CFG.heads, CFG.head_dim))
    got = []
    for i in range(20):
        logits, skv, akv, _ = draft_forward(
            params, adapter, tokens[i:i + 1], skv, akv, i, CFG, use_pallas=False)
        got.append(logits[0])
    np.testing.assert_allclose(jnp.stack(got), want, rtol=2e-4, atol=2e-4)


def test_draft_forward_returns_shallow_hidden(params, adapter):
    tokens = toks(6, 7)
    skv, akv = zkv(CFG.shallow_layers), jnp.zeros((2, CFG.max_seq, CFG.heads, CFG.head_dim))
    _, _, _, shallow = draft_forward(params, adapter, tokens, skv, akv, 0, CFG, False)
    h, _ = input_submodel(params, tokens, zkv(CFG.shallow_layers), 0, CFG, False)
    np.testing.assert_allclose(shallow, h, rtol=1e-5, atol=1e-5)


def test_kv_rollback_by_position_counter(params):
    """Stale KV rows beyond the position counter never affect results —
    the property that makes draft-rejection rollback a counter rewind."""
    tokens = toks(16, 9)
    skv = zkv(CFG.shallow_layers)
    h1, skv = input_submodel(params, tokens[:8], skv, 0, CFG, False)
    # Write garbage "speculative" rows at positions 8..12, then overwrite
    # them by continuing from pos=8 with the real tokens.
    garbage = jnp.asarray([1, 2, 3, 4], jnp.int32)
    _, skv_g = input_submodel(params, garbage, skv, 8, CFG, False)
    h2, _ = input_submodel(params, tokens[8:12], skv_g, 8, CFG, False)
    # Reference: never wrote garbage.
    h2_ref, _ = input_submodel(params, tokens[8:12], skv, 8, CFG, False)
    np.testing.assert_allclose(h2, h2_ref, rtol=1e-5, atol=1e-5)
    del h1


def test_adapter_shapes_and_params(adapter):
    assert param_count(adapter) == CFG.hidden * CFG.hidden * 4 + CFG.hidden
    h = jax.random.normal(jax.random.PRNGKey(2), (5, CFG.hidden))
    akv = jnp.zeros((2, CFG.max_seq, CFG.heads, CFG.head_dim))
    out, akv2 = adapter_forward(adapter, h, akv, 0, CFG, False)
    assert out.shape == (5, CFG.hidden)
    assert akv2.shape == akv.shape
    assert not jnp.allclose(akv2, akv)  # cache was written


def test_medusa_heads_shapes(params):
    mh = init_medusa(jax.random.PRNGKey(3), CFG)
    deep = jax.random.normal(jax.random.PRNGKey(4), (3, CFG.hidden))
    out = medusa_forward(mh, deep, params)
    assert out.shape == (CFG.n_medusa, 3, CFG.vocab)


def test_full_forward_is_causal(params):
    """Changing a future token must not change past logits."""
    t1 = toks(12, 11)
    t2 = t1.at[8].set((t1[8] + 1) % CFG.vocab)
    l1, _, _ = full_forward(params, t1, CFG)
    l2, _, _ = full_forward(params, t2, CFG)
    np.testing.assert_allclose(l1[:8], l2[:8], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[8:], l2[8:])


def test_param_count_matches_formula(params):
    h, f, v, l = CFG.hidden, CFG.ffn, CFG.vocab, CFG.layers
    per_layer = 2 * h + 4 * h * h + 3 * h * f
    expected = v * h + l * per_layer + h + h * v
    assert param_count(params) == expected
